"""Core-planner MLP tests: learnability, determinism, ROC-AUC helper."""
import numpy as np

from repro.core.planner import (
    CorePlanner, PlannerFeatures, INDEXED_PRE, POST_FILTER, PRE_FILTER, roc_auc,
)

F = PlannerFeatures.N_FEATURES


def _toy_problem(n=600, seed=0):
    """Synthetic planner problem: decision boundary is a nonlinear function
    of 'selectivity' and 'corpus size' features (like the real trade-off).
    The sel_is_exact column is held at 0 so ``decide`` stays on the learned
    2-way head (the 3-way promotion has its own test below)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, size=(n, F)).astype(np.float32)
    x[:, PlannerFeatures.SEL_EXACT_COL] = 0.0
    sel, logn = x[:, 3], x[:, 0]
    y = ((sel + 0.3 * logn + 0.1 * np.sin(3 * sel)) > 0).astype(np.int32)
    return x, y


def test_roc_auc_perfect():
    y = np.array([0, 0, 1, 1])
    s = np.array([0.1, 0.2, 0.8, 0.9])
    assert roc_auc(y, s) == 1.0


def test_roc_auc_random():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 2, 2000)
    s = rng.random(2000)
    assert abs(roc_auc(y, s) - 0.5) < 0.05


def test_roc_auc_with_ties():
    y = np.array([0, 1, 0, 1])
    s = np.array([0.5, 0.5, 0.5, 0.5])
    assert abs(roc_auc(y, s) - 0.5) < 1e-9


def test_planner_learns():
    x, y = _toy_problem()
    p = CorePlanner(n_features=F, seed=0).fit(x, y)
    acc = (p.decide(x) == y).mean()
    assert acc > 0.9, f"planner train acc {acc}"


def test_planner_generalises():
    x, y = _toy_problem(800, seed=1)
    xt, yt = x[:600], y[:600]
    xv, yv = x[600:], y[600:]
    p = CorePlanner(n_features=F, seed=0).fit(xt, yt)
    auc = roc_auc(yv, p.predict_proba(xv))
    assert auc > 0.9, f"val AUC {auc}"


def test_planner_three_way_promotion():
    """Rows the 2-way head sends to pre-filtering upgrade to INDEXED_PRE
    exactly when the sel_is_exact feature is set; post rows never change."""
    x, y = _toy_problem(400)
    p = CorePlanner(n_features=F, seed=0).fit(x, y)
    base = (p.predict_proba(x) >= 0.5).astype(np.int32)
    xe = x.copy()
    xe[:, PlannerFeatures.SEL_EXACT_COL] = 1.0
    three = p.decide(xe)
    assert (three[base == POST_FILTER] == POST_FILTER).all()
    assert (three[base == PRE_FILTER] == INDEXED_PRE).all()
    # and with the flag clear, decide IS the 2-way head
    assert np.array_equal(p.decide(x), base)


def test_planner_deterministic():
    x, y = _toy_problem(300)
    p1 = CorePlanner(seed=42).fit(x, y)
    p2 = CorePlanner(seed=42).fit(x, y)
    np.testing.assert_allclose(p1.predict_proba(x), p2.predict_proba(x), atol=1e-5)


def test_planner_tiny_trainset():
    """Regression: with n <= 4 examples the old max(4, n//10) holdout
    swallowed the whole trainset and _train_once ran on zero rows (NaN loss,
    garbage params).  Tiny sets must skip the holdout and still fit."""
    for n in (2, 3, 4):
        rng = np.random.default_rng(n)
        x = rng.normal(size=(n, F)).astype(np.float32)
        y = (np.arange(n) % 2).astype(np.int32)
        p = CorePlanner(seed=0).fit(x, y)
        proba = p.predict_proba(x)
        assert np.isfinite(proba).all(), f"n={n}: non-finite probabilities"
        assert set(p.decide(x).tolist()) <= {PRE_FILTER, POST_FILTER, INDEXED_PRE}


def test_planner_batched_predict_matches_rows():
    """predict_proba on a (B, F) matrix (one jit dispatch, pow2-padded batch)
    must match per-row calls."""
    x, y = _toy_problem(300)
    p = CorePlanner(seed=0).fit(x, y)
    batched = p.predict_proba(x[:37])          # non-pow2 B exercises padding
    rows = np.concatenate([p.predict_proba(x[i]) for i in range(37)])
    np.testing.assert_allclose(batched, rows, atol=1e-6)


def test_planner_proba_range():
    x, y = _toy_problem(300)
    p = CorePlanner(seed=0).fit(x, y)
    proba = p.predict_proba(x)
    assert (proba >= 0).all() and (proba <= 1).all()


# ----------------------------------------------------------------------
# routing head + checkpoint-state backward compatibility
# ----------------------------------------------------------------------
def test_routing_head_learns_and_is_deterministic():
    """The softmax routing head recovers a feature-aligned class split and
    two same-seed fits route identically."""
    x, _ = _toy_problem(500, seed=2)
    classes = ("flat:exact", "ivf:fast", "acorn:precise")
    y = (np.digitize(x[:, 3], [-0.5, 0.5])).astype(np.int32)   # 3 bands on 'sel'
    p1 = CorePlanner(n_features=F, seed=0).fit_routing(x, y, classes)
    p2 = CorePlanner(n_features=F, seed=0).fit_routing(x, y, classes)
    r1, r2 = p1.route(x), p2.route(x)
    assert p1.route_classes == classes
    assert (r1 == y).mean() > 0.9, f"routing train acc {(r1 == y).mean()}"
    np.testing.assert_array_equal(r1, r2)


def test_routing_ignores_unrouted_rows():
    """Rows labelled -1 (legacy / no winner) are excluded from the fit."""
    x, _ = _toy_problem(300, seed=4)
    y = (x[:, 3] > 0).astype(np.int32)
    y[::3] = -1
    p = CorePlanner(n_features=F, seed=0).fit_routing(x, y, ("a:x", "b:y"))
    keep = y >= 0
    assert (p.route(x)[keep] == y[keep]).mean() > 0.9


def test_state_dict_roundtrip_with_routing():
    x, y = _toy_problem(300)
    classes = ("flat:exact", "ivf:fast")
    ry = (x[:, 3] > 0).astype(np.int32)
    p = CorePlanner(n_features=F, seed=0).fit(x, y).fit_routing(x, ry, classes)
    q = CorePlanner(n_features=F, seed=9).load_state(p.state_dict())
    np.testing.assert_allclose(q.predict_proba(x), p.predict_proba(x), atol=1e-6)
    assert q.route_classes == classes
    np.testing.assert_array_equal(q.route(x), p.route(x))


def test_pre_routing_state_loads_plan_only():
    """Backward compat: a checkpoint written BEFORE the routing head existed
    (no 'route' subtree) must load and serve plan-only decisions."""
    x, y = _toy_problem(300)
    p = CorePlanner(n_features=F, seed=0).fit(x, y)
    legacy = p.state_dict()
    assert "route" not in legacy            # no head fitted -> no subtree
    q = CorePlanner(n_features=F, seed=1).load_state(legacy)
    assert q.route_classes is None and q.route(x) is None
    np.testing.assert_allclose(q.predict_proba(x), p.predict_proba(x), atol=1e-6)
    # and loading a legacy state over a ROUTED planner clears the stale head
    r = CorePlanner(n_features=F, seed=0).fit(x, y).fit_routing(
        x, (x[:, 3] > 0).astype(np.int32), ("a:x", "b:y"))
    r.load_state(legacy)
    assert r.route_classes is None and r.route(x) is None
