"""Training substrate: optimizer, schedules, train loop convergence, grad accum."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import Model
from repro.train import AdamWConfig, adamw_init, adamw_update, init_train_state, make_train_step
from repro.train.schedule import warmup_cosine


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for i in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, grads, state, cfg, jnp.float32(1.0))
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, gnorm = adamw_update(params, grads, state, cfg, jnp.float32(1.0))
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_schedule_warmup_and_decay():
    s = np.array([warmup_cosine(i, warmup=10, total=100) for i in [0, 5, 10, 50, 100]])
    assert s[0] == 0.0 and s[1] < s[2]
    assert s[2] >= s[3] >= s[4]


def test_train_loss_decreases():
    cfg = get_config("gemma2-2b").reduced()
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3)))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    first = last = None
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i % 3).items()}
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_grad_accum_matches_full_batch():
    cfg = get_config("qwen3-14b").reduced()
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    s1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), grad_accum=1))
    s2 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), grad_accum=2))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    # same data, same update (up to bf16 accumulation noise)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), st1.params, st2.params
    )
    assert max(jax.tree.leaves(d)) < 5e-3


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "hymba-1.5b", "--reduced", "--steps", "8",
        "--seq-len", "32", "--batch", "4",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
    ])
    assert len(losses) == 8
    # resume from checkpoint: should start at step 8 and do nothing more
    losses2 = main([
        "--arch", "hymba-1.5b", "--reduced", "--steps", "8",
        "--seq-len", "32", "--batch", "4",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
    ])
    assert len(losses2) == 0  # already complete -> clean resume path
