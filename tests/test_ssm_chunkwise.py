"""Chunkwise mLSTM must match the per-step recurrent cell exactly (they share
the (C, n, m) state contract: prefill uses chunkwise, decode uses the cell).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm


def _params_and_x(cfg, s, b=2, seed=0):
    key = jax.random.PRNGKey(seed)
    p = ssm.mlstm_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, cfg.d_model), jnp.float32) * 0.5
    return p, x


def _stepwise_reference(p, x, cfg):
    """Run the O(1) decode cell over every position."""
    b, s, d = x.shape
    state = ssm.mlstm_state(b, cfg)
    ys = []
    for t in range(s):
        y, state = ssm.mlstm_step(p, x[:, t, :], cfg, state)
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("s", [1, 7, 256, 300])
def test_chunkwise_matches_stepwise(s):
    cfg = dataclasses.replace(
        get_config("xlstm-1.3b").reduced(), dtype="float32"
    )
    p, x = _params_and_x(cfg, s)
    y_seq, st_seq = ssm.mlstm_seq(p, x, cfg)
    y_ref, st_ref = _stepwise_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    # final states must agree so decode can continue from a chunked prefill
    np.testing.assert_allclose(np.asarray(st_seq["n"]), np.asarray(st_ref["n"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_seq["m"]), np.asarray(st_ref["m"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_seq["C"]), np.asarray(st_ref["C"]),
                               rtol=2e-4, atol=2e-4)


def test_chunkwise_grad_finite():
    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(), dtype="float32")
    p, x = _params_and_x(cfg, 512)

    def loss(p):
        y, _ = ssm.mlstm_seq(p, x, cfg)
        return jnp.mean(y**2)

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_mamba_chunked_matches_unchunked():
    cfg = dataclasses.replace(get_config("hymba-1.5b").reduced(), dtype="float32")
    key = jax.random.PRNGKey(0)
    p = ssm.mamba_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, cfg.d_model), jnp.float32) * 0.5
    y_chunked, st1 = ssm.mamba_seq(p, x, cfg)           # 512 % 256 == 0 -> chunked
    y_plain, st2 = ssm.mamba_seq(p, x[:, :300, :], cfg)  # 300 -> plain scan
    y_chunk_prefix = np.asarray(y_chunked)[:, :300]
    np.testing.assert_allclose(y_chunk_prefix, np.asarray(y_plain), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1["conv"]).shape, np.asarray(st2["conv"]).shape)
