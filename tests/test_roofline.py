"""Roofline machinery: HLO collective parser + analytic cost model sanity."""
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.analytics import analytic_cost
from repro.launch.roofline import HW, analyse, collective_bytes

HLO = """
HloModule jit_step
%fused (x: bf16[16,4096,144]) -> bf16[16,4096,144] { ... }
ENTRY %main {
  %ag = f32[256,512]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256]
  %ar = bf16[128,64]{1,0} all-reduce(%x1), channel_id=2, to_apply=%add
  %rs = (f32[32,32]{1,0}, f32[32,32]{1,0}) reduce-scatter(%a, %b), channel_id=3
  %cp = f32[16,16]{1,0} collective-permute(%c), channel_id=4
  %ags = f32[64]{0} all-gather-start(%d), channel_id=5
  %agd = f32[64]{0} all-gather-done(%ags)
  %notacoll = f32[8,8]{1,0} add(%e, %f)
}
"""


def test_collective_parser_counts_and_bytes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 256 * 512 * 4 + 64 * 4       # ag + ag-start
    assert out["all-reduce"] == 128 * 64 * 2
    assert out["reduce-scatter"] == 2 * 32 * 32 * 4          # tuple shape
    assert out["collective-permute"] == 16 * 16 * 4
    assert out["count"] == 5                                  # -done not counted


def test_analyse_identifies_bottleneck():
    class A:
        flops = 1e18          # global
        hbm_bytes = 1e12
        coll_bytes_per_dev = 1e6

    t = analyse({"flops": 1.0, "bytes accessed": 1.0}, HLO, chips=256,
                model_flops=5e17, analytic=A)
    assert t.bottleneck == "compute"
    assert abs(t.compute_s - 1e18 / (256 * HW["peak_flops"])) < 1e-9
    assert 0.49 < t.useful_ratio < 0.51


@pytest.mark.parametrize("arch", ["gemma2-2b", "olmoe-1b-7b", "xlstm-1.3b"])
def test_analytic_flops_scale_with_tokens(arch):
    cfg = get_config(arch)
    t4k = analytic_cost(cfg, SHAPES["train_4k"], n_data=16, n_model=16)
    p32 = analytic_cost(cfg, SHAPES["prefill_32k"], n_data=16, n_model=16)
    dec = analytic_cost(cfg, SHAPES["decode_32k"], n_data=16, n_model=16)
    # train does fwd+bwd+remat on 1M tokens; prefill fwd-only on 1M tokens
    # (prefill attention is quadratic in its 8x longer context, so the ratio
    # sits well below the naive 4x for attention-heavy small models)
    assert 2.0 < t4k.flops / p32.flops < 6.0
    # decode is one token per sequence: orders of magnitude below prefill
    assert dec.flops < p32.flops / 1000


def test_analytic_train_flops_near_8nd():
    """Dense train flops ~ 8*N*D (6ND + remat refwd 2ND) + attention."""
    cfg = get_config("qwen3-14b")
    shape = SHAPES["train_4k"]
    ac = analytic_cost(cfg, shape, 16, 16)
    nd = cfg.n_params() * shape.global_batch * shape.seq_len
    assert 7.0 * nd < ac.flops < 12.0 * nd


def test_moe_active_params():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.n_active_params() < cfg.n_params() / 3
    dense = get_config("qwen3-14b")
    assert dense.n_active_params() == dense.n_params()
